"""Flat parameter plane: one contiguous lane-aligned buffer per dtype.

Every per-leaf sweep of a parameter pytree costs one kernel launch and one
HBM round trip per leaf, and every per-leaf collective costs one ppermute per
leaf. Flattening the tree into a single padded buffer per dtype makes the hot
loop's cost independent of the tree's shape: the fused Pallas update
(:mod:`repro.kernels.fused_update`) becomes ONE bandwidth-bound pass and the
distributed gossip exchange (:mod:`repro.core.gossip_dist`) becomes ONE
collective-permute per round (see benchmarks/fused_step.py for the byte
accounting).

Layout: leaves are bucketed by dtype and concatenated in ``jax.tree.flatten``
order; each leaf is zero-padded to a multiple of ``LANE`` (=128) elements so
every leaf starts lane-aligned (the TPU vector lane width). The
:class:`FlatSpec` (offsets/shapes/dtypes) is fully static — built once per
trainer and reused across steps — and :meth:`FlatSpec.unflatten` produces
slice+reshape views that XLA fuses into consumers rather than materializing
copies.

``leading`` dims (the stacked replica axis of both engines) pass through
untouched: a ``[W, ...]``-stacked tree flattens to ``[W, total]`` buffers, so
per-replica scalars (gossip gates/coefficients) broadcast along axis 0.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANE = 128   # TPU vector lane width (elements); every leaf offset aligns to it


def _align(n: int, a: int = LANE) -> int:
    return ((n + a - 1) // a) * a


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one leaf inside its dtype bucket."""
    bucket: str                # dtype bucket key (canonical dtype name)
    offset: int                # element offset within the bucket (lane-aligned)
    size: int                  # elements per item (leading dims excluded)
    shape: Tuple[int, ...]     # per-item shape (leading dims excluded)
    dtype: Any                 # storage dtype the leaf unflattens to


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static layout of a pytree on the flat plane (cache one per trainer)."""
    treedef: Any
    leading: int                    # number of leading (replica) dims passed through
    lead_shape: Tuple[int, ...]
    slots: Tuple[LeafSlot, ...]     # one per leaf, flatten order
    totals: Dict[str, int]          # bucket -> padded total elements
    align: int = LANE               # per-leaf padding granularity (elements)

    @staticmethod
    def build(tree: PyTree, leading: int = 0, align: int = LANE) -> "FlatSpec":
        """Layout for ``tree`` (arrays or ShapeDtypeStructs); the first
        ``leading`` dims of every leaf are shared pass-through (replica) dims."""
        leaves, treedef = jax.tree.flatten(tree)
        assert leaves, "cannot build a FlatSpec over an empty tree"
        lead_shape = tuple(int(d) for d in leaves[0].shape[:leading])
        offsets: Dict[str, int] = {}
        slots: List[LeafSlot] = []
        for x in leaves:
            assert tuple(int(d) for d in x.shape[:leading]) == lead_shape, (
                "all leaves must share the leading dims", x.shape, lead_shape)
            shape = tuple(int(d) for d in x.shape[leading:])
            size = int(np.prod(shape)) if shape else 1
            bucket = jnp.dtype(x.dtype).name
            off = offsets.setdefault(bucket, 0)
            slots.append(LeafSlot(bucket, off, size, shape, jnp.dtype(x.dtype)))
            offsets[bucket] = off + _align(size, align)
        return FlatSpec(treedef, leading, lead_shape, tuple(slots), dict(offsets), align)

    # FlatSpec rides as STATIC pytree metadata (the aux_data of
    # repro.api.state.FlatState), so it must be hashable; the auto-generated
    # frozen-dataclass hash would choke on the ``totals`` dict.
    def __hash__(self):
        return hash((self.treedef, self.leading, self.lead_shape, self.slots,
                     tuple(sorted(self.totals.items())), self.align))

    def with_lead(self, lead_shape: Tuple[int, ...]) -> "FlatSpec":
        """The same layout bound to different leading (replica) dims — slots
        and totals are per-item, so only the pass-through dims change. Used at
        the boundaries: ``with_lead(())`` unflattens one replica row or an
        EASGD center, ``with_lead((W,))`` a whole stacked plane."""
        return dataclasses.replace(self, leading=len(lead_shape),
                                   lead_shape=tuple(int(d) for d in lead_shape))

    # ------------------------------------------------------------------ sizes
    @property
    def buckets(self) -> Tuple[str, ...]:
        return tuple(self.totals)

    def num_elements(self, bucket: Optional[str] = None) -> int:
        if bucket is not None:
            return self.totals[bucket]
        return sum(self.totals.values())

    # ------------------------------------------------------------------- ops
    def flatten(self, tree: PyTree) -> Dict[str, jax.Array]:
        """Tree -> one ``[*lead, total]`` buffer per dtype bucket.

        Bucketing follows the SPEC, not the argument's dtypes, so a float32
        gradient tree flattens into the layout of its bfloat16 parameter spec
        bucket-for-bucket (the buffers then carry the argument's dtype)."""
        leaves = jax.tree.flatten(tree)[0]
        assert len(leaves) == len(self.slots), (len(leaves), len(self.slots))
        parts: Dict[str, List[jax.Array]] = {}
        for x, s in zip(leaves, self.slots):
            flat = jnp.reshape(x, self.lead_shape + (s.size,))
            padded = _align(s.size, self.align)
            if padded != s.size:
                flat = jnp.pad(flat, [(0, 0)] * self.leading + [(0, padded - s.size)])
            parts.setdefault(s.bucket, []).append(flat)
        return {k: (v[0] if len(v) == 1 else jnp.concatenate(v, axis=-1))
                for k, v in parts.items()}

    def unflatten(self, bufs: Dict[str, jax.Array],
                  like: Optional[PyTree] = None) -> PyTree:
        """Buffers -> tree of slice/reshape views. ``like`` (optional)
        supplies per-leaf dtypes to cast to instead of the spec's storage
        dtypes (e.g. a velocity tree restored from promoted buffers)."""
        if like is not None:
            dts = [jnp.dtype(x.dtype) for x in jax.tree.flatten(like)[0]]
        else:
            dts = [s.dtype for s in self.slots]
        leaves = []
        for s, dt in zip(self.slots, dts):
            b = bufs[s.bucket]
            v = jax.lax.slice_in_dim(b, s.offset, s.offset + s.size, axis=-1)
            leaves.append(jnp.reshape(v, self.lead_shape + s.shape).astype(dt))
        return jax.tree.unflatten(self.treedef, leaves)

    def views(self, bufs: Dict[str, jax.Array]) -> PyTree:
        """:meth:`unflatten` with a SCATTER-based VJP — the flat-resident
        engines' loss boundary. Differentiating a loss through plain slice
        views gives each leaf a ``pad``-to-full-plane cotangent that XLA
        materializes separately (temp memory ∝ leaves x plane); this variant
        lands every leaf's cotangent in ONE zeros buffer per dtype bucket via
        in-place ``dynamic_update_slice`` (slots are disjoint), so gradients
        arrive already flat at plane-sized memory, with no concatenate and no
        per-leaf pads — step memory stays independent of tree depth."""
        return _views(self, bufs)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _views(spec: FlatSpec, bufs: Dict[str, jax.Array]) -> PyTree:
    return spec.unflatten(bufs)


def _views_fwd(spec, bufs):
    return _views(spec, bufs), None


def _views_bwd(spec, _res, ct):
    leaves = jax.tree.flatten(ct)[0]
    out = {k: jnp.zeros(spec.lead_shape + (n,), jnp.dtype(k))
           for k, n in spec.totals.items()}
    for g, s in zip(leaves, spec.slots):
        if s.size == 0:
            continue
        flat = jnp.reshape(g, spec.lead_shape + (s.size,)).astype(jnp.dtype(s.bucket))
        out[s.bucket] = jax.lax.dynamic_update_slice_in_dim(
            out[s.bucket], flat, s.offset, axis=len(spec.lead_shape))
    return (out,)


_views.defvjp(_views_fwd, _views_bwd)
