from repro.common import config, hardware, pytree  # noqa: F401
from repro.common.config import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    ProtocolConfig,
    TrainConfig,
)
