"""JAX version-compatibility shims.

The codebase targets the modern sharding surface (``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``, ``jax.shard_map(..., axis_names=...)``,
two-argument ``AbstractMesh``), but the pinned container JAX predates parts of
it. Every call site goes through this module so each API difference is handled
in exactly one place; when the pin moves forward the shims become pass-throughs
and can be deleted without touching callers.
"""
from __future__ import annotations

import enum
from typing import Any, FrozenSet, Iterable, Optional, Sequence

import jax

try:  # jax >= 0.4.38
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    _HAS_AXIS_TYPE = True
except ImportError:  # older jax: meshes are implicitly fully "auto"
    _HAS_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Optional[Sequence[Any]] = None,
              devices: Optional[Sequence[Any]] = None):
    """``jax.make_mesh`` accepting ``axis_types`` on every supported version.

    On JAX without ``AxisType`` the argument is dropped: those versions treat
    every mesh axis as auto, which is exactly what the repo requests.
    """
    kwargs = {"devices": devices} if devices is not None else {}
    if _HAS_AXIS_TYPE and axis_types is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=tuple(axis_types), **kwargs)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Device-free ``AbstractMesh`` across the (shape, names) vs.
    ((name, size), ...) constructor generations."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:  # jax <= 0.4.37: single shape_tuple of (name, size)
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: old JAX returns a one-element
    list of dicts, new JAX a plain dict; both become a (possibly empty) dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def set_mesh(mesh):
    """Ambient-mesh context manager: ``jax.set_mesh`` on new JAX; on old JAX a
    ``Mesh`` is itself a context manager with the equivalent effect."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# Old-JAX shard_map emulates partial-manual via `auto=`, but its SPMD
# partitioner miscompiles when the auto axes are non-trivial (>1 devices):
# "Check failed: target.IsManualSubgroup() == sharding().IsManualSubgroup()".
# (Informational — since the flat-plane refactor every gossip shard_map runs
# FULL-manual with unfiltered specs, so no caller branches on this anymore.)
PARTIAL_MANUAL_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f, mesh, in_specs, out_specs,
              manual_axes: Iterable[str] = ()) -> Any:
    """Partial-manual ``shard_map``: ``manual_axes`` are manual, every other
    mesh axis stays auto (GSPMD). Maps onto ``jax.shard_map(axis_names=...)``
    on new JAX and ``jax.experimental.shard_map.shard_map(auto=...)`` on old,
    with replication checking disabled on both (the gossip updates are
    deliberately worker-varying)."""
    manual: FrozenSet[str] = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                             axis_names=manual, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)
