from repro.serving.engine import ServeProgram, consensus_params, make_serve_program  # noqa: F401
