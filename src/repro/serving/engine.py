"""Serving engine: consensus-parameter prefill + batched single-token decode.

Inference uses the consensus (worker-averaged) parameters — gossip is a
training-time protocol, so serving is the standard path of the framework:
params without the replica dim, batch sharded over all data axes
('pod','worker','fsdp'), weights sharded ('fsdp','model') 2-D (big replicas
must spread beyond the model axis; the per-layer all-gather this implies is a
measured roofline term and a §Perf hillclimb subject).

KV-cache sharding adapts per arch (DESIGN.md §4): kv-head-sharded over
'model' when the head count divides, else sequence-sharded over 'model'.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import MeshConfig, ModelConfig
from repro.launch import sharding as shr
from repro.models import transformer as tr

PyTree = Any


def serve_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    rules = dict(shr.DEFAULT_RULES)
    rules.update({
        "batch": ("pod", "worker", "fsdp"),
        "kv_heads": ("model",),
        "seq_kv": ("model",),
    })
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if cfg.mla is None and cfg.num_kv_heads % model_size == 0:
        rules["seq_kv"] = ()    # prefer head sharding; keep 'model' free for it
    return rules


@dataclasses.dataclass
class ServeProgram:
    model_cfg: ModelConfig
    mesh: Mesh
    param_specs: PyTree          # PartitionSpec tree (single replica)
    param_shapes: PyTree         # ShapeDtypeStruct tree
    cache_specs: PyTree
    cache_shapes: PyTree
    decode_fn: Callable          # jit'd (params, cache, tokens[, cond]) -> (logits, cache)
    prefill_fn: Optional[Callable]
    batch: int
    max_len: int
    window: int
    # continuous-batching decode: (params, cache, tokens, cond, kv_start[B])
    # -> (logits, cache); compiled lazily, so programs that never serve
    # per-slot traffic pay nothing (repro.serve harness)
    decode_slots_fn: Optional[Callable] = None
    param_dtype: Any = jnp.bfloat16
    cache_dtype: Any = jnp.bfloat16

    # ----------------------------------------------------------- swap surface
    def place_params(self, params: PyTree) -> PyTree:
        """Device-put a single-replica parameter pytree onto the serving
        shardings, cast to the program's serving dtype — the hot-swap entry
        point (repro.serve.LiveServer): the transfer is DISPATCHED here, not
        awaited, so a swap never blocks the token loop on the copy."""
        cast = jax.tree.map(lambda x, r: jnp.asarray(x, r.dtype),
                            params, self.param_shapes)
        shardings = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                                 self.param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(cast, shardings)

    def init_cache(self) -> PyTree:
        """Fresh zero KV-cache (pos = 0) matching ``cache_specs`` — the
        continuous-batching harness's starting state."""
        from repro.models import transformer as tr
        cache, _ = tr.init_cache(self.model_cfg, self.batch, self.max_len,
                                 dtype=self.cache_dtype, window=self.window)
        return cache

    def token_shapes(self, seq: int = 1):
        cfg = self.model_cfg
        if cfg.audio is not None:
            return jax.ShapeDtypeStruct((self.batch, cfg.audio.num_codebooks, seq), jnp.int32)
        return jax.ShapeDtypeStruct((self.batch, seq), jnp.int32)

    def cond_shapes(self):
        cfg = self.model_cfg
        if cfg.audio is not None:
            return jax.ShapeDtypeStruct((self.batch, cfg.audio.num_cond_tokens, cfg.d_model),
                                        jnp.bfloat16)
        if cfg.vlm is not None:
            return jax.ShapeDtypeStruct((self.batch, cfg.vlm.num_image_tokens,
                                         cfg.vlm.image_embed_dim), jnp.bfloat16)
        return None


def make_serve_program(mesh: Mesh, mesh_cfg: MeshConfig, cfg: ModelConfig, *,
                       batch: int, max_len: int, window: int = 0,
                       param_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
                       with_prefill: bool = False, prefill_len: int = 0) -> ServeProgram:
    rules = serve_rules(cfg, mesh)
    param_shapes, param_axes = tr.abstract_lm(cfg, param_dtype)
    param_specs = shr.tree_specs(param_shapes, param_axes, mesh, rules)
    cache_shapes, cache_axes = tr.abstract_cache(cfg, batch, max_len,
                                                 dtype=cache_dtype, window=window)
    cache_specs = shr.tree_specs(cache_shapes, cache_axes, mesh, rules)
    data_axes = tuple(a for a in ("pod", "worker", "fsdp") if a in mesh.axis_names)
    n_data = 1
    for a, s in zip(mesh.axis_names, mesh.devices.shape):
        if a in data_axes:
            n_data *= s
    # batch must divide across the data axes to shard it; else replicate (long_500k B=1)
    bshard = NamedSharding(mesh, P(data_axes) if batch % n_data == 0 else P())
    rep = NamedSharding(mesh, P())

    def shard(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    def decode(params, cache, tokens, cond):
        logits, new_cache = tr.decode_step(params, cfg, cache, tokens, cond, window=window)
        return logits, new_cache

    decode_fn = jax.jit(
        decode,
        in_shardings=(shard(param_specs), shard(cache_specs), bshard, bshard),
        out_shardings=(bshard, shard(cache_specs)),
        donate_argnums=(1,))

    def decode_slots(params, cache, tokens, cond, kv_start):
        logits, new_cache = tr.decode_step(params, cfg, cache, tokens, cond,
                                           window=window, kv_start=kv_start)
        return logits, new_cache

    decode_slots_fn = jax.jit(
        decode_slots,
        in_shardings=(shard(param_specs), shard(cache_specs), bshard, bshard, bshard),
        out_shardings=(bshard, shard(cache_specs)),
        donate_argnums=(1,))

    prefill_fn = None
    if with_prefill:
        def pf(params, tokens, cond):
            return tr.prefill(params, cfg, tokens, cond, cache_dtype=cache_dtype,
                              max_len=max_len)

        prefill_fn = jax.jit(
            pf,
            in_shardings=(shard(param_specs), bshard, bshard),
            out_shardings=(bshard, shard(cache_specs)))

    return ServeProgram(cfg, mesh, param_specs, param_shapes, cache_specs, cache_shapes,
                        decode_fn, prefill_fn, batch, max_len, window,
                        decode_slots_fn=decode_slots_fn,
                        param_dtype=param_dtype, cache_dtype=cache_dtype)


def consensus_bufs(theta) -> dict:
    """FLAT-NATIVE consensus: mean over the ``W`` replica rows of the resident
    ``{bucket: [W, total]}`` buffers — ONE einsum reduction per dtype bucket,
    no pytree stacking, no per-leaf sweeps. This is the reduction every
    consensus consumer shares (serving handoff, SnapshotBus publish, the sim
    engine's aggregate path)."""
    out = {}
    for k, v in theta.items():
        w = v.shape[0]
        out[k] = (jnp.einsum("wn->n", v.astype(jnp.float32)) / w).astype(v.dtype)
    return out


def consensus_params(state_or_stack) -> PyTree:
    """Worker-averaged parameters -> serving params (paper 'Aggregate').

    Accepts either a flat-resident :class:`repro.api.FlatState` (the native
    path: mean over the ``[W, total]`` buffers via :func:`consensus_bufs`,
    then ONE unflatten into lazy views) or a legacy ``[W, ...]``-stacked
    pytree. This is the training->serving handoff: ``repro.api.GossipTrainer
    .consensus_params(state)`` delegates here, and ``make_serve_program`` is
    re-exported from :mod:`repro.api` as the serving entry point."""
    from repro.api.state import FlatState
    if isinstance(state_or_stack, FlatState):
        s = state_or_stack
        return s.spec.with_lead(()).unflatten(consensus_bufs(s.theta))
    return jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
                        state_or_stack)
