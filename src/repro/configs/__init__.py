"""Architecture config registry.

Each assigned architecture is a module exposing ``CONFIG`` (the full,
assignment-exact ModelConfig) and ``reduced()`` (a smoke-test variant of the
same family: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.common.config import ModelConfig

ARCH_IDS = (
    "tinyllama_1_1b",
    "deepseek_v2_lite_16b",
    "xlstm_125m",
    "granite_20b",
    "grok_1_314b",
    "granite_3_8b",
    "musicgen_large",
    "gemma2_9b",
    "llama_3_2_vision_11b",
    "zamba2_2_7b",
)

# dashed aliases (assignment spelling) -> module name
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "tinyllama-1.1b": "tinyllama_1_1b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "xlstm-125m": "xlstm_125m",
    "granite-20b": "granite_20b",
    "grok-1-314b": "grok_1_314b",
    "granite-3-8b": "granite_3_8b",
    "musicgen-large": "musicgen_large",
    "gemma2-9b": "gemma2_9b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "zamba2-2.7b": "zamba2_2_7b",
})


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ALIASES.get(name, name)}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ALIASES.get(name, name)}")
    return mod.reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
