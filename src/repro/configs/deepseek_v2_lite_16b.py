"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: MLA kv_lora=512, MoE 64 routed
top-6 + 2 shared experts, first layer dense.

Assignment note (DESIGN.md §5): the assignment line mixes V2-Lite (64e) and
V2 (160e) numbers; we implement the Lite spec matching the primary
"MoE 64e top-6" designation.
"""
import dataclasses
from repro.common.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", arch_type="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, rope_theta=10000.0,
    activation="swiglu", source="arXiv:2405.04434",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  d_ff_expert=1408, first_dense_layers=1),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512,
        mla=MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      d_ff_expert=128, first_dense_layers=1))
