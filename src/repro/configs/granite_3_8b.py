"""Granite-3.0 8B [hf:ibm-granite/granite-3.0-2b-base family]: GQA kv=8."""
import dataclasses
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", arch_type="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=12800, vocab_size=49155, activation="swiglu",
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="granite3-reduced", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512)
