"""TinyLlama-1.1B [arXiv:2401.02385]: llama2-arch small, GQA kv=4."""
import dataclasses
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", arch_type="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32000, rope_theta=10000.0,
    activation="swiglu", source="arXiv:2401.02385",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="tinyllama-reduced", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512)
