"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens
(4 codebooks, vocab 2048 each), cross-attention to stubbed conditioning
frame embeddings (the text/melody encoder is the assignment's frontend stub).
"""
import dataclasses
from repro.common.config import AudioConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", arch_type="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, activation="gelu", source="arXiv:2306.05284",
    audio=AudioConfig(num_codebooks=4, num_cond_tokens=64),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="musicgen-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=256,
        audio=AudioConfig(num_codebooks=2, num_cond_tokens=8))
