"""Grok-1 314B [hf:xai-org/grok-1]: MoE 8 experts top-2, GQA kv=8."""
import dataclasses
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", arch_type="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, activation="geglu",
    source="hf:xai-org/grok-1",
    moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=0,
                  d_ff_expert=32768, first_dense_layers=0),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="grok-reduced", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=512))
