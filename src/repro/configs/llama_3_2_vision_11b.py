"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision]: 40 self-attn
layers + 8 gated cross-attention blocks to stubbed vision-patch embeddings
(ViT encoder + projector are the assignment's frontend stub)."""
import dataclasses
from repro.common.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", arch_type="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0, activation="swiglu",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    vlm=VLMConfig(cross_attn_layers=(3, 8, 13, 18, 23, 28, 33, 38),
                  num_image_tokens=1601, image_embed_dim=4096),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama-vision-reduced", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, d_ff=512, vocab_size=512,
        vlm=VLMConfig(cross_attn_layers=(0,), num_image_tokens=16,
                      image_embed_dim=256))
