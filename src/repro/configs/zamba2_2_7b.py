"""Zamba2-2.7B [arXiv:2411.15242]: 54 Mamba2 layers + 2 alternating shared
attention+MLP blocks applied every 6 layers, ssm_state=64."""
import dataclasses
from repro.common.config import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, activation="gelu", source="arXiv:2411.15242",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_dim=4, chunk_size=256),
    hybrid=HybridConfig(shared_attn_every=6, num_shared_blocks=2),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_dim=4, chunk_size=16),
        hybrid=HybridConfig(shared_attn_every=1, num_shared_blocks=2))
