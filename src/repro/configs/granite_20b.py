"""Granite-20B code model [arXiv:2405.04324]: llama-arch, MQA (kv=1)."""
import dataclasses
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", arch_type="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, activation="gelu", source="arXiv:2405.04324",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="granite20b-reduced", num_layers=2, d_model=384,
        num_heads=6, num_kv_heads=1, d_ff=768, vocab_size=512)
