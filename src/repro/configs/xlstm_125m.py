"""xLSTM-125M [arXiv:2405.04517]: sLSTM + mLSTM blocks, no separate FFN."""
import dataclasses
from repro.common.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m", arch_type="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, activation="gelu", source="arXiv:2405.04517",
    xlstm=XLSTMConfig(slstm_every=6, slstm_offset=5, proj_factor=2.0, conv_dim=4),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-reduced", num_layers=2, d_model=128,
        num_heads=2, num_kv_heads=2, vocab_size=512,
        xlstm=XLSTMConfig(slstm_every=2, slstm_offset=1, proj_factor=2.0, conv_dim=4))
