"""Gemma2-9B [arXiv:2408.00118]: alternating local(4096)/global attention,
attn softcap 50, final softcap 30, post-norms, GeGLU, head_dim=256."""
import dataclasses
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", arch_type="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
    d_ff=14336, vocab_size=256000, head_dim=256, activation="geglu",
    local_window=4096, attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norms=True, sw_decode_window=4096, source="arXiv:2408.00118",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="gemma2-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
        head_dim=64, local_window=16)
