"""Observer — binds an :class:`repro.common.config.ObsConfig` to a
:class:`TraceRecorder` / :class:`MetricsSink` and hangs off the engine hooks.

The cardinal rule (the inert-anchor contract): observation NEVER adds device
ops to a step program. Every event is reconstructed host-side from values the
engines already materialize —

- gate/partner draws are pure functions of the PRE-step PRNG key, re-derived
  through the engine's own ``_draw_fn`` (the async clock program's pattern);
- flow-control admission replays ``FlowControl.allow_np`` on the pre-step
  token balances (bit-identical host mirror of the traced gate);
- fault drop/corrupt draws replay the pure ``(seed, worker, step)`` hashes
  (``FaultModel.drop_mask`` / ``corrupt_mask``);
- partition chunk ids replay ``partition_ids_np``;
- message-mode wire events are emitted by the async pending queue itself,
  which is host code to begin with;
- metrics counters are DELTAS of the engine's ``ProtocolState`` accumulators
  (one batched ``jax.device_get`` per sampled step) — sink totals equal the
  state's totals exactly, by construction.

Timestamps: VIRTUAL seconds on the async engine's worker tracks, host wall
seconds since recorder start everywhere else (the trainer track mixes in wall
time under ``engine="async"`` — a documented, deliberate asymmetry: virtual
time is the async engine's semantic clock).

The harvest is PIPELINED one step behind: each hook dispatches its device
reads (the ``_draw_fn`` draws, a jitted donation-safe snapshot of the
``ProtocolState`` accumulators) without blocking and materializes the
PREVIOUS step's reads — by then they are computed, so the ``device_get``
overlaps with the step the engine just dispatched instead of stalling it.
That one-step lag is why the recording overhead stays in the low single
digits; :meth:`flush` (called by :meth:`export`) drains the last pending
step. The snapshot copies are what make the lag safe against the engines'
donated step buffers.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import numpy as np

from repro.obs.metrics import MetricsSink
from repro.obs.trace import TraceRecorder

# ProtocolState scalar accumulators mirrored into the metrics stream (the
# fields are Optional — only the ones the run's planes seeded are read)
PROTO_COUNTERS = (
    "comm_rounds", "comm_units", "comm_bytes",
    "stale_time", "stale_steps", "stale_events",
    "wire_dropped", "wire_corrupt", "exch_timeouts", "exch_retries",
    "flow_skipped",
)
# small per-worker / per-chunk arrays, recorded as lists
PROTO_ARRAYS = ("tokens", "chunk_units")


class Observer:
    """One per recording ``GossipTrainer`` (see module docstring)."""

    def __init__(self, cfg, engine: str, num_workers: int):
        self.cfg = cfg
        self.engine = engine
        self.num_workers = num_workers
        self.trace: Optional[TraceRecorder] = (
            TraceRecorder(cfg.max_events) if cfg.trace_enabled() else None)
        self.sink: Optional[MetricsSink] = (
            MetricsSink(cfg.metrics_path or None)
            if cfg.metrics_enabled() else None)
        self._t0 = time.perf_counter()
        self._prev: Dict[str, float] = {}
        self._exported = False
        # one-step-deferred harvest state (see module docstring)
        self._pending_trace = None
        self._pending_row = None
        self._snap_fn = None

    # ------------------------------------------------------------ utilities
    def now(self) -> float:
        """Host wall seconds since recorder start."""
        return time.perf_counter() - self._t0

    def want(self, step: int) -> bool:
        return step % max(1, self.cfg.sample_every) == 0

    @property
    def tracing(self) -> bool:
        return self.trace is not None

    def event(self, ev: str, t: float, step: int, worker: int = -1,
              **fields) -> None:
        if self.trace is not None:
            self.trace.emit(ev, t, step, worker, **fields)

    # ---------------------------------------------------------- engine hooks
    def on_sim_step(self, trainer, t_start: float, key0, step0,
                    tokens0) -> None:
        """Synchronous engine: one whole-fleet compute span (wall time) plus
        the step's exchange/fault/flow/chunk events re-derived from the
        pre-step key (dispatched now, harvested one step later)."""
        if self.trace is None:
            return
        step = int(step0)   # pre-step scalar copy: already materialized
        if not self.want(step):
            self._flush_trace()
            return
        t = self.now()
        self.event("compute", t_start, step, worker=-1, dur=t - t_start)
        self._defer_exchanges(trainer, t, step, key0, step0, tokens0,
                              mask=None)

    def on_async_window(self, trainer, t: float, mask, nxt, clocks0,
                        key0, step0, tokens0) -> None:
        """Async engine: per-worker compute spans in VIRTUAL time plus (in
        normal mode) the window's exchange events at window time ``t``.
        Message-mode wire events come from the pending queue instead."""
        if self.trace is None:
            return
        step = int(step0)
        if not self.want(step):
            self._flush_trace()
            return
        for w in np.nonzero(mask)[0]:
            w = int(w)
            self.event("compute", float(clocks0[w]), step, worker=w,
                       dur=float(nxt[w]) - float(clocks0[w]))
        if getattr(trainer, "_message_mode", False):
            self._flush_trace()
        else:
            self._defer_exchanges(trainer, t, step, key0, step0, tokens0,
                                  mask=np.array(mask, copy=True))

    def on_dist_step(self, backend, t_start: float, step: int, fire,
                     active, rnd: int) -> None:
        """Distributed engine: everything is already host-side — the schedule
        poll gives fire/active/round, the matching gives the partners, and
        the per-device wire bytes are static. Nothing to defer."""
        if self.trace is None or not self.want(step):
            return
        t = self.now()
        self.event("compute", t_start, step, worker=-1, dur=t - t_start)
        if not fire or active is None:
            return
        partners = np.asarray(backend.matching_partners(rnd))
        act = np.asarray(active).astype(bool)
        wire = float(backend.wire_bytes())
        for i in np.nonzero(act)[0]:
            i = int(i)
            k = int(partners[i])
            if k == i:
                continue
            self.event("exchange", t, step, worker=i, peer=k, round=int(rnd),
                       wire_bytes=wire)

    # -------------------------------------------------- deferred trace harvest
    def _defer_exchanges(self, trainer, t: float, step: int, key0, step0,
                         tokens0, mask) -> None:
        """Dispatch the gate/peer draws for THIS step (no blocking read) and
        harvest the PREVIOUS step's — the device_get then overlaps with the
        engine step that was just dispatched instead of stalling behind it.
        key0/step0/tokens0 are pre-step copies, safe against donation."""
        if not trainer._impl.pairwise:
            self._flush_trace()
            return
        draws = trainer._draw_fn(key0, step0)
        self._flush_trace()
        self._pending_trace = (trainer, t, step, draws, tokens0, mask)

    def _flush_trace(self) -> None:
        """Materialize the deferred step's draws and classify each initiation
        into exchange / drop / corrupt / flow_skip (+ a chunk id under the
        partition plane) — the same precedence the traced step applies."""
        p = self._pending_trace
        if p is None:
            return
        self._pending_trace = None
        trainer, t, step, draws, tokens0, mask = p
        import jax
        gate, peers, balances = jax.device_get((*draws, tokens0))
        gate = np.asarray(gate).astype(bool)
        peers = np.asarray(peers)
        active = gate if mask is None else (gate & np.asarray(mask))
        if trainer.flow is not None and balances is not None:
            balances = np.asarray(balances)
            allowed = np.asarray(
                trainer.flow.allow_np(step, balances)).astype(bool)
            for w in np.nonzero(active & ~allowed)[0]:
                w = int(w)
                self.event("flow_skip", t, step, worker=w,
                           tokens=float(balances[w]))
            active = active & allowed
        part = None
        if trainer.partition > 1:
            from repro.fleet.partition import partition_ids_np
            part = partition_ids_np(trainer.fleet.seed, step,
                                    trainer.num_workers, trainer.partition)
        fm = trainer.fault_model
        for i in np.nonzero(active)[0]:
            i = int(i)
            k = int(peers[i])
            if k == i:
                continue
            if fm is not None and fm.injects_drop and \
                    bool(fm.drop_mask(i, step)):
                self.event("drop", t, step, worker=i)
                continue
            if fm is not None and fm.injects_corrupt and \
                    bool(fm.corrupt_mask(i, step)):
                self.event("corrupt", t, step, worker=i)
                continue
            self.event("exchange", t, step, worker=i, peer=k)
            if part is not None:
                self.event("chunk", t, step, worker=i, chunk=int(part[i]))

    # --------------------------------------------------------- facade metrics
    def on_step(self, step: int, metrics: Dict[str, Any], state) -> None:
        """One sampled metrics row: the normalized step metrics plus a
        donation-safe snapshot of the ``ProtocolState`` accumulators (ONE
        jitted copy dispatch), harvested one step later."""
        if self.sink is None:
            return
        if not self.want(step):
            self._flush_row()
            return
        row: Dict[str, Any] = {"step": step, "t": self.now(),
                               "engine": self.engine}
        for k in ("loss", "loss_mean", "loss_max", "fired", "comm_active",
                  "comm_round", "comm_bytes", "virtual_time", "window_size",
                  "pending_wires", "published_seq", "publish_rejected"):
            if k in metrics:
                row[k] = metrics[k]
        proto = getattr(state, "proto", None)
        snap = None
        if proto is not None:
            import jax
            vals = {k: getattr(proto, k) for k in PROTO_COUNTERS + PROTO_ARRAYS
                    if getattr(proto, k, None) is not None}
            if self._snap_fn is None:
                # x * 1 is a bit-exact copy into FRESH output buffers — the
                # engine donates this state's buffers on its next step, so
                # holding the originals across the lag would read freed memory
                self._snap_fn = jax.jit(
                    lambda d: {k: v * 1 for k, v in d.items()})
            snap = self._snap_fn(vals)
        self._flush_row()
        self._pending_row = (row, snap)

    def _flush_row(self) -> None:
        p = self._pending_row
        if p is None:
            return
        self._pending_row = None
        row, snap = p
        if snap is not None:
            import jax
            host = jax.device_get(snap)
            pr = {}
            for k in PROTO_COUNTERS:
                if k not in host:
                    continue
                v = float(host[k])
                pr[k] = v
                delta = v - self._prev.get(k, 0.0)
                self._prev[k] = v
                if delta:
                    self.sink.counter_add(k, delta)
                if k == "stale_time" and delta:
                    self.sink.observe("stale_time_delta", delta)
            for k in PROTO_ARRAYS:
                if k in host:
                    pr[k] = np.asarray(host[k]).tolist()
            row["proto"] = pr
            # row fields that alias the (now possibly donated) state read
            # their values from the snapshot instead
            if "comm_bytes" in pr:
                row["comm_bytes"] = pr["comm_bytes"]
            if "comm_round" in row and "comm_rounds" in pr:
                row["comm_round"] = int(pr["comm_rounds"])
        elif "comm_bytes" in row:
            # dist without a ProtocolState: the host f64 accumulator is the
            # authoritative comm account; mirror it into the proto block so
            # the report tool reads one shape
            v = float(row["comm_bytes"])
            row["proto"] = {"comm_bytes": v}
            delta = v - self._prev.get("comm_bytes", 0.0)
            self._prev["comm_bytes"] = v
            if delta:
                self.sink.counter_add("comm_bytes", delta)
        for k in ("window_size", "pending_wires"):
            if k in row:
                self.sink.observe(k, int(row[k]))
        self.sink.record(row)

    def flush(self) -> None:
        """Drain the one-step-deferred harvest (call before reading the
        recorder/sink mid-run; :meth:`export` does it for you)."""
        self._flush_trace()
        self._flush_row()

    # ---------------------------------------------------------------- export
    def export(self, trace_path: Optional[str] = None,
               metrics_path: Optional[str] = None) -> Dict[str, str]:
        """Write the trace (Perfetto JSON) and flush/close the metrics JSONL.
        Paths default to the config's; returns {kind: path} for what was
        written. Idempotent for the trace (re-export overwrites)."""
        self.flush()
        out = {}
        tp = trace_path or self.cfg.trace_path
        if self.trace is not None and tp:
            self.trace.save(tp, num_workers=self.num_workers)
            out["trace"] = tp
        mp = metrics_path or self.cfg.metrics_path
        if self.sink is not None:
            if mp and mp != (self.sink.path or ""):
                # late path (CLI --metrics after in-memory recording): dump
                # the buffered rows
                import json
                with open(mp, "w") as f:
                    for r in self.sink.records:
                        f.write(json.dumps(r) + "\n")
                out["metrics"] = mp
            elif self.sink.path:
                out["metrics"] = self.sink.path
            self.sink.close()
        self._exported = True
        return out
