"""MetricsSink — counters / gauges / histograms + JSONL record streaming.

The sink is the metrics half of the telemetry plane: engines (through the
facade observer) and the serve loop push

- **counters** — monotone totals, fed by DELTAS of the engine's own
  ``ProtocolState`` accumulators (comm_bytes, stale_time, wire_dropped, ...)
  so sink totals are exactly the state's totals, never a re-derivation;
- **gauges** — last-value scalars (pending_wires, virtual_time, ...);
- **histograms** — raw observation lists with summary stats (swap pauses,
  snapshot staleness, per-window staleness increments).

``record(row)`` streams one JSON object per line to ``path`` (opened lazily,
flushed per row so a crashed run keeps its telemetry) and keeps the rows
in memory for :func:`repro.obs.report` / tests.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional


def _jsonable(v):
    """Best-effort scalar conversion for device arrays / numpy scalars."""
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    return v


class MetricsSink:
    """Counter/gauge/histogram registry with optional JSONL streaming."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, List[float]] = {}
        self.records: List[Dict[str, Any]] = []
        self._fh = None

    # ------------------------------------------------------------ registry
    def counter_add(self, name: str, value) -> None:
        self.counters[name] = self.counters.get(name, 0) + _jsonable(value)

    def gauge_set(self, name: str, value) -> None:
        self.gauges[name] = _jsonable(value)

    def observe(self, name: str, value) -> None:
        self.hists.setdefault(name, []).append(_jsonable(value))

    def samples(self, name: str) -> List[float]:
        """The LIVE observation list for ``name`` (mutations — e.g. a
        benchmark's ``.clear()`` between phases — are seen by the sink)."""
        return self.hists.setdefault(name, [])

    # ----------------------------------------------------------- streaming
    def record(self, row: Dict[str, Any]) -> None:
        row = {k: _jsonable(v) for k, v in row.items()}
        self.records.append(row)
        if self.path is not None:
            if self._fh is None:
                self._fh = open(self.path, "w")
            self._fh.write(json.dumps(row) + "\n")
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # ------------------------------------------------------------- summary
    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        out.update(self.counters)
        out.update(self.gauges)
        for name, vals in self.hists.items():
            n = len(vals)
            out[f"{name}_count"] = n
            out[f"{name}_mean"] = (sum(vals) / n) if n else 0.0
            out[f"{name}_max"] = max(vals) if n else 0.0
        return out
