"""Run summarizer: ``python -m repro.obs.report run.jsonl [--trace run.json]``.

Reads a metrics JSONL stream (one row per sampled facade step, written by
:class:`repro.obs.metrics.MetricsSink` through the facade observer) and
prints

- the run's cumulative ``ProtocolState`` totals (comm bytes/units/rounds,
  staleness, faults, flow skips) — read from the LAST row's ``proto`` block,
  so they match the engine's own accumulators EXACTLY, never re-derived;
- the wire-bytes-vs-loss frontier (the paper's headline trade-off): loss at
  evenly spaced communication budgets along the run;
- a staleness histogram over the per-row ``stale_time`` increments.

With ``--trace`` it additionally validates the exported Perfetto trace
against the event schema and prints per-type event counts.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def totals(rows: List[Dict[str, Any]]) -> Dict[str, float]:
    """Cumulative ProtocolState accumulators at the last sampled step."""
    for r in reversed(rows):
        if r.get("proto"):
            return dict(r["proto"])
    return {}


def frontier(rows: List[Dict[str, Any]], points: int = 10) -> List[Dict[str, float]]:
    """(step, comm_bytes, loss) at ``points`` evenly spaced rows — loss as a
    function of spent communication budget."""
    rows = [r for r in rows if "loss" in r and "comm_bytes" in r]
    if not rows:
        return []
    idx = sorted({round(i * (len(rows) - 1) / max(points - 1, 1))
                  for i in range(points)})
    return [{"step": rows[i]["step"],
             "comm_bytes": float(rows[i]["comm_bytes"]),
             "loss": float(rows[i]["loss"])} for i in idx]


def staleness_hist(rows: List[Dict[str, Any]], bins: int = 8):
    """Histogram over per-row stale_time increments (virtual/wall seconds of
    partner-row age accumulated per sampled step)."""
    deltas, prev = [], 0.0
    for r in rows:
        st = (r.get("proto") or {}).get("stale_time")
        if st is None:
            continue
        if st > prev:
            deltas.append(st - prev)
        prev = st
    if not deltas:
        return [], []
    lo, hi = min(deltas), max(deltas)
    width = (hi - lo) / bins or 1.0
    counts = [0] * bins
    for d in deltas:
        counts[min(int((d - lo) / width), bins - 1)] += 1
    edges = [lo + i * width for i in range(bins + 1)]
    return edges, counts


def summarize(rows: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Machine-readable summary (what the benchmark / tests assert on)."""
    return {"rows": len(rows), "totals": totals(rows),
            "frontier": frontier(rows), "final_loss":
            float(rows[-1]["loss"]) if rows and "loss" in rows[-1] else None}


def print_report(rows: List[Dict[str, Any]]) -> None:
    tot = totals(rows)
    print(f"# {len(rows)} sampled steps")
    if tot:
        print("\n## ProtocolState totals (exact engine accumulators)")
        for k in sorted(tot):
            v = tot[k]
            print(f"  {k:>14}: {v}")
    fr = frontier(rows)
    if fr:
        print("\n## wire-bytes-vs-loss frontier")
        print(f"  {'step':>6} {'comm_MB':>10} {'loss':>10}")
        for p in fr:
            print(f"  {p['step']:>6} {p['comm_bytes']/1e6:>10.3f} "
                  f"{p['loss']:>10.4f}")
    edges, counts = staleness_hist(rows)
    if counts:
        print("\n## staleness histogram (stale_time increments per step)")
        peak = max(counts)
        for i, c in enumerate(counts):
            bar = "#" * round(40 * c / peak)
            print(f"  [{edges[i]:8.3f}, {edges[i+1]:8.3f}) {c:>5} {bar}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs.report")
    ap.add_argument("metrics", help="metrics JSONL from a --metrics run")
    ap.add_argument("--trace", default="",
                    help="optionally validate an exported trace JSON too")
    args = ap.parse_args(argv)
    rows = load_jsonl(args.metrics)
    print_report(rows)
    if args.trace:
        from repro.obs.schema import validate_trace
        with open(args.trace) as f:
            doc = json.load(f)
        errs = validate_trace(doc)
        by_type: Dict[str, int] = {}
        for e in doc.get("reproEvents", []):
            by_type[e.get("ev", "?")] = by_type.get(e.get("ev", "?"), 0) + 1
        print(f"\n## trace {args.trace}: "
              f"{len(doc.get('reproEvents', []))} events "
              f"({', '.join(f'{k}={v}' for k, v in sorted(by_type.items()))})")
        if errs:
            print("SCHEMA ERRORS:")
            for e in errs[:20]:
                print(f"  {e}")
            return 1
        print("schema: VALID")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
