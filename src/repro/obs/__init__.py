"""repro.obs — the unified telemetry plane.

One observability layer over every engine, the fault/fleet planes and the
serve loop: typed event tracing (:class:`TraceRecorder` → Perfetto/Chrome
timeline), a metrics registry (:class:`MetricsSink` → JSONL stream), and the
ONE documented step-metrics schema (:mod:`repro.obs.schema`) all engines
return through the facade.

Quickstart::

    from repro.api import GossipTrainer
    from repro.common.config import ObsConfig

    trainer = GossipTrainer(engine="async", ..., obs=ObsConfig(
        trace_path="run.json", metrics_path="run.jsonl"))
    state = trainer.init_state(0)
    for step in range(200):
        state, m = trainer.step(state, next(batches))
    trainer.export_obs()                   # writes run.json + run.jsonl
    # python -m repro.obs.report run.jsonl --trace run.json
    # -> totals, wire-bytes-vs-loss frontier, staleness histogram
    # load run.json at https://ui.perfetto.dev for the timeline

The all-default ``ObsConfig()`` is INERT (the repo's anchor contract): no
observer is constructed and every engine reproduces the un-observed build
bit-exactly. Recording never perturbs training either — all events are
host-side reconstructions of draws the engines already consume
(:mod:`repro.obs.observer`).
"""
from repro.obs.metrics import MetricsSink
from repro.obs.observer import Observer
from repro.obs.schema import (ASYNC_MESSAGE_KEYS, ASYNC_STEP_KEYS,
                              CORE_STEP_KEYS, EVENT_TYPES, SERVE_STEP_KEYS,
                              normalize_step_metrics, validate_event,
                              validate_trace)
from repro.obs.trace import TraceRecorder

__all__ = ["MetricsSink", "Observer", "TraceRecorder",
           "CORE_STEP_KEYS", "ASYNC_STEP_KEYS", "ASYNC_MESSAGE_KEYS",
           "SERVE_STEP_KEYS", "EVENT_TYPES",
           "normalize_step_metrics", "validate_event", "validate_trace"]
