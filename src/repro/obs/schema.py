"""The unified telemetry schema — ONE documented contract for (a) the
per-step metrics dict every engine returns through ``GossipTrainer.step`` and
(b) the typed events a :class:`repro.obs.trace.TraceRecorder` captures.

Step-metrics schema
-------------------

Every engine's facade step returns AT LEAST :data:`CORE_STEP_KEYS` (the
engine parity surface — tests/test_obs.py asserts identical key sets on
equivalent configs):

- ``step``         facade step index (0-based host counter)
- ``loss``         fleet-mean loss this step (the headline scalar)
- ``loss_mean``    alias of the fleet mean (sim/async compute it per worker;
                   the dist engine reduces on device, so mean == loss)
- ``loss_max``     worst per-worker loss (dist: the device-reduced mean — a
                   documented degeneracy, per-worker losses never leave the
                   mesh there)
- ``fired``        bool — did a communication round fire this step
- ``comm_active``  number of workers that initiated an exchange (0 when the
                   round did not fire)
- ``comm_round``   the engine's gossip-round counter: cumulative fired-round
                   count on sim/async (device-side, lazy), the schedule's
                   round index on dist — same monotonicity, different base
- ``comm_bytes``   cumulative expected per-worker egress (applied-exchange
                   accounting — mirrors ``ProtocolState.comm_bytes``)

Documented per-engine extensions (present exactly when the feature is on):

- :data:`ASYNC_STEP_KEYS` — ``engine="async"`` event windows
- :data:`ASYNC_MESSAGE_KEYS` — async message mode (delay models)
- :data:`SERVE_STEP_KEYS` — the facade ``publish_every`` snapshot hook
  (conditional: only on publishing steps)

Event schema
------------

A trace event is a flat dict with the common required fields ``ev`` (a name
in :data:`EVENT_TYPES`), ``t`` (seconds — VIRTUAL time on the async engine,
host wall time since recorder start elsewhere) and ``step`` (the engine step
counter at emission), plus the per-type required fields listed in
:data:`EVENT_TYPES`. ``worker``/``peer`` are worker indices (-1 = the
whole-fleet/trainer track). :func:`validate_event` / :func:`validate_trace`
are the CI gate for exported traces.
"""
from __future__ import annotations

from typing import Any, Dict, List

# ---------------------------------------------------------------------------
# step-metrics schema
# ---------------------------------------------------------------------------

CORE_STEP_KEYS = frozenset({
    "step", "loss", "loss_mean", "loss_max",
    "fired", "comm_active", "comm_round", "comm_bytes",
})

# engine="async": one facade step is one virtual-time event window
ASYNC_STEP_KEYS = frozenset({
    "virtual_time", "window_size",
    "stale_time", "stale_steps", "stale_events",
})

# async message mode (FaultConfig delay models): host pending-wire queue
ASYNC_MESSAGE_KEYS = frozenset({
    "pending_wires", "exch_timeouts", "exch_retries",
})

# facade publish hook (publish_every=k): only on publishing steps, and only
# one of the two depending on snapshot validation
SERVE_STEP_KEYS = frozenset({"published_seq", "publish_rejected"})


def normalize_step_metrics(metrics: Dict[str, Any], step: int) -> Dict[str, Any]:
    """Fill the CORE keys every engine owes the caller (additive — never
    removes an engine's own keys, so existing consumers keep working).

    The engine backends already emit their natural keys; this normalizes the
    cross-engine differences: the dist path has no per-worker losses (mean ==
    max == loss), the sim path has no ``loss`` alias before the backend adds
    it, etc. Pure host dict manipulation — no device ops, no sync beyond what
    reading the values the backend already returned would cost.
    """
    m = metrics
    m.setdefault("step", step)
    if "loss" not in m and "loss_mean" in m:
        m["loss"] = m["loss_mean"]
    m.setdefault("loss_mean", m.get("loss"))
    m.setdefault("loss_max", m.get("loss_mean"))
    if "comm_active" not in m:
        # dist backends report fired + the per-worker active mask count when
        # they have one; a protocol with no communication has neither
        m["comm_active"] = 0
    m.setdefault("fired", m["comm_active"] > 0)
    m.setdefault("comm_round", -1)
    m.setdefault("comm_bytes", 0.0)
    return m


# ---------------------------------------------------------------------------
# event schema
# ---------------------------------------------------------------------------

# ev name -> the extra required fields beyond (ev, t, step)
EVENT_TYPES: Dict[str, frozenset] = {
    # compute spans: one per in-window worker (async, virtual time) or one
    # whole-fleet span on the trainer track (sim/dist, wall time)
    "compute":  frozenset({"worker", "dur"}),
    # in-window applied exchange: initiator -> sampled peer
    "exchange": frozenset({"worker", "peer"}),
    # message mode (delay models): a wire's life cycle
    "dispatch": frozenset({"worker", "peer", "arrival"}),
    "apply":    frozenset({"worker", "peer", "age", "gap"}),
    "timeout":  frozenset({"worker", "peer", "attempt"}),
    "retry":    frozenset({"worker", "peer", "attempt"}),
    # fault plane: wires lost / failing checksum (counted, never applied)
    "drop":     frozenset({"worker"}),
    "corrupt":  frozenset({"worker"}),
    # fleet plane: flow-control skip (with the refusing token balance) and
    # the partition chunk an initiator shipped
    "flow_skip": frozenset({"worker", "tokens"}),
    "chunk":     frozenset({"worker", "chunk"}),
    # async full-fleet outage (fail_rejoin with slow_worker=-1)
    "outage":   frozenset({"until"}),
    # serve plane: snapshot publishes and hot swaps
    "publish":          frozenset({"seq"}),
    "publish_rejected": frozenset(),
    "swap":             frozenset({"seq", "pause_s"}),
}

_COMMON_REQUIRED = ("ev", "t", "step")


def validate_event(event: Dict[str, Any]) -> List[str]:
    """Problems with one typed event ([] = valid)."""
    errs = []
    for f in _COMMON_REQUIRED:
        if f not in event:
            errs.append(f"missing required field {f!r}")
    ev = event.get("ev")
    if ev not in EVENT_TYPES:
        errs.append(f"unknown event type {ev!r}")
        return errs
    for f in sorted(EVENT_TYPES[ev]):
        if f not in event:
            errs.append(f"{ev}: missing field {f!r}")
    if "t" in event and not isinstance(event["t"], (int, float)):
        errs.append(f"{ev}: t must be a number, got {type(event['t']).__name__}")
    return errs


_PERFETTO_PH = {"X", "i", "I", "s", "f", "M", "C"}


def validate_trace(trace: Dict[str, Any]) -> List[str]:
    """Problems with an exported trace document ([] = valid): the raw typed
    events under ``reproEvents`` validate against :data:`EVENT_TYPES`, and
    the ``traceEvents`` timeline is structurally loadable by Perfetto /
    chrome://tracing (known phases, numeric timestamps, thread-name metadata
    for every referenced track)."""
    errs = []
    if not isinstance(trace.get("traceEvents"), list):
        return ["traceEvents missing or not a list"]
    for i, e in enumerate(trace.get("reproEvents", [])):
        for msg in validate_event(e):
            errs.append(f"reproEvents[{i}]: {msg}")
    named_tids = set()
    used_tids = set()
    for i, e in enumerate(trace["traceEvents"]):
        ph = e.get("ph")
        if ph not in _PERFETTO_PH:
            errs.append(f"traceEvents[{i}]: unknown phase {ph!r}")
            continue
        if ph == "M":
            if e.get("name") == "thread_name":
                named_tids.add(e.get("tid"))
            continue
        if not isinstance(e.get("ts"), (int, float)):
            errs.append(f"traceEvents[{i}]: non-numeric ts")
        if ph == "X" and not isinstance(e.get("dur"), (int, float)):
            errs.append(f"traceEvents[{i}]: complete event without dur")
        if "name" not in e:
            errs.append(f"traceEvents[{i}]: missing name")
        used_tids.add(e.get("tid"))
    for tid in sorted(used_tids - named_tids, key=str):
        errs.append(f"track tid={tid!r} has no thread_name metadata")
    return errs
