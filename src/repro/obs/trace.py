"""TraceRecorder — typed host-side event capture + Perfetto timeline export.

The recorder is a bounded append-only buffer of schema-typed events
(:mod:`repro.obs.schema`). Engines emit at boundaries where they ALREADY
compute the information host-side (the async pending-wire queue, the dist
schedule poll, the re-derived gate/peer draws) — recording never adds device
ops, which is what keeps a recording run bit-exact.

Export is a single JSON document that is BOTH things at once:

- ``traceEvents`` — a Chrome-trace/Perfetto timeline (load it at
  https://ui.perfetto.dev): one track per worker plus a trainer track,
  compute spans as complete events, message-mode wires as slices + flow
  arrows from the initiator's dispatch to the peer's arrival, faults and
  flow skips as instant markers;
- ``reproEvents`` — the raw typed events, the machine-readable record the
  CI schema gate and the report tool consume.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional


class TraceRecorder:
    """Bounded typed-event buffer (see module docstring)."""

    def __init__(self, max_events: int = 1_000_000):
        self.max_events = int(max_events)
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0     # events refused by the ring bound

    def emit(self, ev: str, t: float, step: int, worker: int = -1,
             **fields) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        e = {"ev": ev, "t": float(t), "step": int(step), "worker": int(worker)}
        e.update(fields)
        self.events.append(e)

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------------- perfetto
    def perfetto(self, num_workers: Optional[int] = None) -> Dict[str, Any]:
        """Render the typed events as a Chrome-trace document. Times map
        seconds -> microseconds; worker w lives on tid w+1, the trainer/fleet
        track on tid 0."""
        tev: List[Dict[str, Any]] = []
        pid = 1
        tids = {0}

        def us(t):
            return round(float(t) * 1e6, 3)

        def tid_of(worker):
            tid = int(worker) + 1 if worker is not None and worker >= 0 else 0
            tids.add(tid)
            return tid

        flow_id = 0
        for e in self.events:
            ev, t, w = e["ev"], e["t"], e.get("worker", -1)
            args = {k: v for k, v in e.items()
                    if k not in ("ev", "t") and v is not None}
            if ev == "compute":
                tev.append({"ph": "X", "name": "compute", "cat": "compute",
                            "pid": pid, "tid": tid_of(w), "ts": us(t),
                            "dur": max(us(e["dur"]), 1), "args": args})
            elif ev == "exchange":
                # in-window exchange: a thin slice on the initiator plus an
                # arrow to the peer at the same instant
                flow_id += 1
                tev.append({"ph": "X", "name": f"exchange→{e['peer']}",
                            "cat": "exchange", "pid": pid, "tid": tid_of(w),
                            "ts": us(t), "dur": 1, "args": args})
                tev.append({"ph": "s", "name": "wire", "cat": "exchange",
                            "id": flow_id, "pid": pid, "tid": tid_of(w),
                            "ts": us(t)})
                tev.append({"ph": "f", "bp": "e", "name": "wire",
                            "cat": "exchange", "id": flow_id, "pid": pid,
                            "tid": tid_of(e["peer"]), "ts": us(t) + 1})
            elif ev == "dispatch":
                # message-mode wire: slice spans dispatch -> expected arrival
                # on the initiator track; the arrow lands on the peer
                flow_id += 1
                dur = max(us(e["arrival"]) - us(t), 1)
                tev.append({"ph": "X", "name": f"wire→{e['peer']}",
                            "cat": "wire", "pid": pid, "tid": tid_of(w),
                            "ts": us(t), "dur": dur, "args": args})
                tev.append({"ph": "s", "name": "wire", "cat": "wire",
                            "id": flow_id, "pid": pid, "tid": tid_of(w),
                            "ts": us(t)})
                tev.append({"ph": "f", "bp": "e", "name": "wire",
                            "cat": "wire", "id": flow_id, "pid": pid,
                            "tid": tid_of(e["peer"]), "ts": us(e["arrival"])})
            elif ev == "apply":
                tev.append({"ph": "i", "name": f"apply←{e['worker']}",
                            "cat": "wire", "s": "t", "pid": pid,
                            "tid": tid_of(e["peer"]), "ts": us(t),
                            "args": args})
            elif ev == "outage":
                tev.append({"ph": "X", "name": "outage", "cat": "fault",
                            "pid": pid, "tid": 0, "ts": us(t),
                            "dur": max(us(e["until"]) - us(t), 1),
                            "args": args})
                tids.add(0)
            else:
                # faults, flow skips, chunks, timeouts/retries, serve events:
                # instant thread-scoped markers
                tev.append({"ph": "i", "name": ev, "cat": "marker", "s": "t",
                            "pid": pid, "tid": tid_of(w), "ts": us(t),
                            "args": args})
        if num_workers is not None:
            tids.update(range(1, int(num_workers) + 1))
        meta = [{"ph": "M", "name": "process_name", "pid": pid,
                 "args": {"name": "repro"}}]
        for tid in sorted(tids):
            name = "trainer" if tid == 0 else f"worker {tid - 1}"
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": name}})
        return {"traceEvents": meta + tev,
                "displayTimeUnit": "ms",
                "reproEvents": self.events,
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path: str, num_workers: Optional[int] = None) -> None:
        with open(path, "w") as f:
            json.dump(self.perfetto(num_workers), f)
