from repro.checkpoint import io  # noqa: F401
