"""Checkpointing: flat-key npz for pytrees + json metadata.

Two generations:

- **v2 (flat-resident)** — :func:`save_state` / :func:`restore_state` persist
  a :class:`repro.api.state.FlatState` AS ITS FLAT BUFFERS (one ``[W, total]``
  array per dtype bucket under readable paths like ``theta::float32``),
  together with a JSON **FlatSpec manifest** (leaf paths, offsets, shapes,
  dtypes) in the metadata — the checkpoint is the wire layout, written with
  zero per-leaf traffic, and self-describing enough to be re-assembled into
  pytrees without the producing code.
- **v1 (legacy pytree)** — :func:`save` / :func:`restore`: one npz entry per
  tree leaf. :func:`restore_state` detects v1 payloads and converts them
  bit-exactly into the requested FlatState (flattening is deterministic), so
  pre-FlatState checkpoints resume seamlessly.

Both generations persist the gossip scheduler's host-side state so a run can
resume with bit-identical protocol behavior (same PRNG stream position):
``schedule=sched`` stores :meth:`repro.core.scheduler.GossipSchedule.state`
in the metadata and :func:`restore_schedule` rewinds a scheduler from it. The
``repro.api.GossipTrainer`` facade calls these from its
``save_checkpoint``/``load_checkpoint``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
SEP = "::"

FLAT_FORMAT = 2       # checkpoint format version written by save_state

# optional FlatState payload keys: the async engine's virtual-time fields,
# the fault-plane counters (repro.faults) and the fleet-plane fields
# (repro.fleet: token balances, flow-skip and per-chunk exchange counters)
# are None (hence absent) in checkpoints written by engines not using them —
# a cross-engine restore keeps the template's (zero-initialized) values
VIRTUAL_TIME_KEYS = tuple(
    f"proto{SEP}{k}" for k in ("clocks", "worker_steps", "stale_time",
                               "stale_steps", "stale_events",
                               "wire_dropped", "wire_corrupt",
                               "exch_timeouts", "exch_retries",
                               "tokens", "flow_skipped", "chunk_units"))


def _path_key(path) -> str:
    return SEP.join(
        str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
        for p in path)


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(path) or "_root"] = np.asarray(leaf)
    return flat


def save(path: str, tree: PyTree, meta: Optional[dict] = None,
         schedule=None) -> None:
    """Atomically save a pytree; ``schedule`` (a GossipSchedule) is persisted
    into the metadata so :func:`restore_schedule` can rewind it on resume."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp.removesuffix(".npz"), **_flatten(tree))
    os.replace(tmp, path)
    if schedule is not None:
        meta = dict(meta or {})
        meta["schedule"] = schedule.state()
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore(path: str, like: PyTree, missing_ok: Tuple[str, ...] = ()) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    ``missing_ok``: key prefixes that may be absent from the payload — those
    leaves keep ``like``'s values instead of raising (used for optional
    engine-specific state, e.g. the async virtual-time fields when loading a
    checkpoint written by a synchronous engine)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, ref in paths:
        key = _path_key(path_keys) or "_root"
        if key not in flat and any(key == m or key.startswith(m + SEP)
                                   for m in missing_ok):
            leaves.append(jnp.asarray(ref))
            continue
        arr = flat[key]
        assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
        leaves.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Checkpoint v2: flat-resident FlatState payloads + FlatSpec manifest
# ---------------------------------------------------------------------------

def _leaf_keys(spec) -> List[str]:
    """Per-slot path-key strings of the spec's parameter tree, flatten order
    (matches the v1 per-leaf npz keys under any given prefix)."""
    token = jax.tree_util.tree_unflatten(spec.treedef, list(range(len(spec.slots))))
    entries = jax.tree_util.tree_flatten_with_path(token)[0]
    keys = [None] * len(spec.slots)
    for path, idx in entries:
        keys[idx] = _path_key(path)
    return keys


def flat_spec_manifest(spec) -> dict:
    """JSON-serializable description of a FlatSpec: enough to locate every
    parameter inside the saved flat buffers without the producing code."""
    return {
        "leading": spec.leading,
        "lead_shape": list(spec.lead_shape),
        "align": spec.align,
        "totals": {k: int(n) for k, n in spec.totals.items()},
        "slots": [{"path": key, "bucket": s.bucket, "offset": s.offset,
                   "size": s.size, "shape": list(s.shape), "dtype": s.dtype.name}
                  for key, s in zip(_leaf_keys(spec), spec.slots)],
    }


def load_payload(path: str) -> Dict[str, np.ndarray]:
    """Raw flat-key payload of a checkpoint npz, exactly as written (v2 keys
    are whole planes like ``theta::float32``). The in-memory snapshot path
    (:mod:`repro.serve.snapshot`) reads buffers back through this instead of
    re-deriving them, so the on-disk and in-memory forms stay one format."""
    with np.load(path) as data:
        return {k: data[k] for k in data.files}


def check_manifest(meta: Optional[dict], spec, path: str = "") -> None:
    """Raise unless ``meta``'s FlatSpec manifest (if any) matches ``spec``.

    v2 stores whole planes under bucket keys, so leaf identity lives in the
    manifest, not the npz keys (v1 failed loudly on renamed leaves via its
    per-leaf path keys) — slicing a saved plane with a reordered layout would
    silently scramble parameters. Shared by :func:`restore_state` and the
    snapshot bus's on-disk round trip."""
    saved = (meta or {}).get("flat_spec")
    if saved is not None and saved != flat_spec_manifest(spec):
        raise ValueError(
            "checkpoint FlatSpec manifest does not match the target "
            "state's layout (parameter tree renamed/reordered/resized "
            "since the checkpoint was written?) — refusing to slice the "
            f"saved plane with a different layout: {path}")


def save_state(path: str, state, meta: Optional[dict] = None,
               schedule=None) -> None:
    """Persist a :class:`repro.api.state.FlatState` in checkpoint format v2:
    the resident flat buffers under named paths plus the FlatSpec manifest
    (and optionally the gossip schedule) in the metadata."""
    meta = dict(meta or {})
    meta["format"] = FLAT_FORMAT
    meta["flat_spec"] = flat_spec_manifest(state.spec)
    save(path, state.state_dict(), meta=meta, schedule=schedule)


def _legacy_to_state(flat: Dict[str, np.ndarray], like):
    """Convert a v1 per-leaf-pytree payload (SimState/TrainState era) into
    ``like``'s FlatState structure, bit-exactly (flattening is
    deterministic). Handles both legacy layouts: the sim engine's
    ``{params, opt(step, mu, nu), proto, key, step, comm}`` NamedTuple dump
    and the dist engine's ``{params, velocity, center, step, comm}``."""
    spec = like.spec
    leaf_keys = _leaf_keys(spec)

    def tree_bufs(prefix: str, lead: bool = True):
        keys = [prefix + SEP + k if k else prefix for k in leaf_keys]
        if not all(k in flat for k in keys):
            return None
        leaves = [jnp.asarray(flat[k]) for k in keys]
        tree = jax.tree_util.tree_unflatten(spec.treedef, leaves)
        return (spec if lead else spec.with_lead(())).flatten(tree)

    def scalar(key, ref):
        return jnp.asarray(flat[key], dtype=ref.dtype) if key in flat else ref

    theta = tree_bufs("params")
    assert theta is not None, "legacy checkpoint is missing the params tree"
    # velocity: the sim engine stored it as the opt NamedTuple's ``mu``
    # attribute (keys ``opt::.mu::<leaf>``), the dist engine as a top-level
    # ``velocity`` field
    mu = tree_bufs("velocity")
    if mu is None:
        mu = tree_bufs(f"opt{SEP}.mu")
    assert mu is not None or not getattr(like.opt, "mu", None), (
        "legacy checkpoint is missing the velocity tree")
    nu = tree_bufs(f"opt{SEP}.nu")
    # the dist v1 layout had no optimizer step of its own — fall back to the
    # trainer step so the two (redundant) counters resume in agreement
    opt = type(like.opt)(scalar(f"opt{SEP}.step", scalar("step", like.opt.step)),
                         mu if mu is not None else {},
                         nu if nu is not None else {})
    proto = like.proto
    if proto is not None:
        # _replace keeps fields legacy payloads never had (the async engine's
        # virtual-time bookkeeping) at the template's values instead of None
        proto = proto._replace(
            center=tree_bufs(f"proto{SEP}.center", lead=False),
            comm_rounds=scalar(f"proto{SEP}.comm_rounds", proto.comm_rounds),
            comm_units=scalar(f"proto{SEP}.comm_units", proto.comm_units),
            comm_bytes=scalar(f"proto{SEP}.comm_bytes", proto.comm_bytes))
    comm = like.comm
    if comm is not None and getattr(comm, "residual", None) is not None:
        comm = type(comm)(tree_bufs(f"comm{SEP}.residual"))
    center = tree_bufs("center", lead=False) if like.center is not None else None
    key = jnp.asarray(flat["key"]) if "key" in flat else like.key
    return like.replace(theta=theta, opt=opt, proto=proto, comm=comm,
                        center=center, key=key,
                        step=scalar("step", like.step))


def restore_state(path: str, like, meta: Optional[dict] = None):
    """Restore a checkpoint into the FlatState structure of ``like``.

    The generation comes from ``meta['format']`` (written by
    :func:`save_state`; pass an already-loaded ``meta`` to skip re-reading
    it); checkpoints without metadata fall back to payload sniffing (a
    ``theta::<bucket>`` key exists only in v2). v2 payloads restore the flat
    buffers directly; v1 (legacy pytree) payloads convert through
    :func:`_legacy_to_state` — an old checkpoint resumes into the resident
    layout bit-exactly."""
    if meta is None:
        meta = load_meta(path) or {}
    fmt = meta.get("format")
    if fmt is None:
        with np.load(path) as data:
            fmt = (FLAT_FORMAT if any(k.startswith("theta" + SEP) or k == "theta"
                                      for k in data.files) else 1)
    if int(fmt) >= FLAT_FORMAT:
        check_manifest(meta, like.spec, path)
        return like.from_state_dict(restore(path, like.state_dict(),
                                            missing_ok=VIRTUAL_TIME_KEYS))
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    return _legacy_to_state(flat, like)


def restore_schedule(path: str, schedule) -> bool:
    """Rewind a :class:`~repro.core.scheduler.GossipSchedule` to the position
    saved alongside the checkpoint at ``path``. Returns True when schedule
    state was present and restored."""
    meta = load_meta(path)
    if meta and meta.get("schedule"):
        schedule.restore(meta["schedule"])
        return True
    return False


def load_meta(path: str) -> Optional[dict]:
    mp = path + ".meta.json"
    if os.path.exists(mp):
        with open(mp) as f:
            return json.load(f)
    return None


def latest_step_path(ckpt_dir: str) -> Optional[Tuple[int, str]]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and name.endswith(".npz"):
            step = int(name[len("step_"):-len(".npz")])
            if best is None or step > best[0]:
                best = (step, os.path.join(ckpt_dir, name))
    return best
