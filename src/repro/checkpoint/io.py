"""Checkpointing: flat-key npz for pytrees + json metadata.

Handles the trainer's full state (stacked replicas, velocity, EASGD center,
step) and the gossip scheduler's host-side state, so a run can resume with
bit-identical protocol behavior (same PRNG stream position):
:func:`save` accepts ``schedule=sched`` to persist
:meth:`repro.core.scheduler.GossipSchedule.state` in the metadata and
:func:`restore_schedule` rewinds a scheduler from it. The
``repro.api.GossipTrainer`` facade calls both from its
``save_checkpoint``/``load_checkpoint``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
SEP = "::"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path)
        flat[key or "_root"] = np.asarray(leaf)
    return flat


def save(path: str, tree: PyTree, meta: Optional[dict] = None,
         schedule=None) -> None:
    """Atomically save a pytree; ``schedule`` (a GossipSchedule) is persisted
    into the metadata so :func:`restore_schedule` can rewind it on resume."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp.removesuffix(".npz"), **_flatten(tree))
    os.replace(tmp, path)
    if schedule is not None:
        meta = dict(meta or {})
        meta["schedule"] = schedule.state()
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, ref in paths:
        key = SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path_keys) or "_root"
        arr = flat[key]
        assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
        leaves.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_schedule(path: str, schedule) -> bool:
    """Rewind a :class:`~repro.core.scheduler.GossipSchedule` to the position
    saved alongside the checkpoint at ``path``. Returns True when schedule
    state was present and restored."""
    meta = load_meta(path)
    if meta and meta.get("schedule"):
        schedule.restore(meta["schedule"])
        return True
    return False


def load_meta(path: str) -> Optional[dict]:
    mp = path + ".meta.json"
    if os.path.exists(mp):
        with open(mp) as f:
            return json.load(f)
    return None


def latest_step_path(ckpt_dir: str) -> Optional[Tuple[int, str]]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and name.endswith(".npz"):
            step = int(name[len("step_"):-len(".npz")])
            if best is None or step > best[0]:
                best = (step, os.path.join(ckpt_dir, name))
    return best
