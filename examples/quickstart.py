"""Quickstart: train a reduced TinyLlama with Elastic Gossip across 4
simulated workers on CPU, compare against All-reduce, and report the
consensus (aggregate) model's loss.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.launch.train import run  # noqa: E402


def main():
    print("== Elastic Gossip (p=0.25, alpha=0.5), 4 workers ==")
    _, hist_eg = run("tinyllama_1_1b", reduced=True, steps=40, method="elastic_gossip",
                     p=0.25, tau=0, alpha=0.5, workers=4, global_batch=8, seq=64,
                     lr=3e-3)
    print("\n== All-reduce SGD baseline (same data, same init) ==")
    _, hist_ar = run("tinyllama_1_1b", reduced=True, steps=40, method="allreduce",
                     p=0.0, tau=0, alpha=0.5, workers=4, global_batch=8, seq=64,
                     lr=3e-3)
    print(f"\nfinal loss: elastic_gossip={hist_eg[-1]['loss']:.4f} "
          f"allreduce={hist_ar[-1]['loss']:.4f}")
    print("Elastic Gossip reaches comparable loss while communicating ~1/4 "
          "of the steps, pairwise instead of all-to-all (paper Tables 4.1/4.3).")


if __name__ == "__main__":
    main()
