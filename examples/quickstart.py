"""Quickstart for the ``repro.api`` surface: train the paper's MNIST MLP
(§4.1) with Elastic Gossip across 4 simulated workers, compare against the
All-reduce SGD baseline, and report Rank-0 / Aggregate (consensus) accuracy
plus the *measured* communication bytes — the paper's headline trade-off, from
one facade:

    trainer = GossipTrainer(engine="sim", protocol=..., loss_fn=..., num_workers=4)
    state = trainer.init_state(seed)
    state, metrics = trainer.step(state, (x, y))     # scheduling is internal

Swap ``engine="dist"`` (plus a mesh) to run the same protocol on the
production shard_map engine — see repro/launch/train.py, which is this loop
at scale. Any protocol registered with ``@register_protocol`` works here by
name (``available_protocols()`` lists them).

The trainer state is a flat-RESIDENT ``repro.api.FlatState``: parameters and
velocity LIVE as one lane-aligned buffer per dtype (repro/common/flat.py) —
the wire layout — from ``init_state`` to checkpoint, flattened exactly once.
``state.params`` / ``state.velocity`` are lazy slice views for the
boundaries (eval, checkpoints, ``rank0_params``/``consensus_params``); the
hot loop never re-flattens (zero per-step concat copies — the jaxpr is
regression-tested). On this plane the distributed gossip round is a single
collective-permute and NAG + the gossip displacement land in one fused
Pallas pass with the buffers donated in place
(repro/kernels/fused_update.py). Pass ``fused_update=False`` to
``GossipTrainer`` to force the per-bucket reference path — numerically
equivalent (parity-tested), just more HBM sweeps; see
benchmarks/fused_step.py / BENCH_fused_step.json for the byte accounting and
the resident-vs-reflatten steps/sec.

The wire itself is compressible (repro/comm): ``codec="q8"`` quantizes the
flat plane to stochastic-rounded int8 (+ per-block scales) before it leaves
the worker, cutting measured egress ~4x on top of the gossip savings — the
``comm_bytes`` metric and ``comm_cost()`` then report true wire bytes, and
the mixing mathematically sees the quantization error, so the accuracy cost
is measured, not assumed. ``codec="topk"`` (magnitude top-k + error-feedback
residual) pushes further; ``@register_codec`` adds your own
(benchmarks/comm_compress.py / BENCH_comm_compress.json for the numbers).

Heterogeneous fleets are one keyword away (repro.hetero): ``engine="async"``
plus a ``HeteroConfig`` runs the SAME protocol on an event-driven virtual-time
simulator — each worker's clock advances by a pluggable compute-time model
(lognormal stragglers below), local steps fire per worker, exchanges carry
per-exchange staleness accounting in ``ProtocolState``, and a homogeneous
``constant`` model reproduces ``engine="sim"`` bit-exactly
(tests/test_hetero.py). See benchmarks/straggler.py / BENCH_straggler.json
for the virtual-time win over the synchronous barrier under a 4x straggler.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.api import GossipTrainer, available_protocols
from repro.common.config import HeteroConfig, OptimizerConfig, ProtocolConfig
from repro.data.partition import batches_for_step, partition_iid
from repro.data.synthetic import load_mnist
from repro.models import simple

WORKERS, STEPS, BATCH = 4, 300, 128


def train_one(method: str, train, test, codec: str = "none", **proto_kw):
    proto = ProtocolConfig(method=method, topology="uniform", codec=codec,
                           **proto_kw)
    params0, _ = simple.init_mlp(jax.random.PRNGKey(0), in_dim=784, hidden=128,
                                 depth=2, num_classes=10)

    def loss_fn(params, x, y):
        return simple.xent_loss(simple.mlp_logits(params, x), y)

    trainer = GossipTrainer(engine="sim", protocol=proto,
                            optimizer=OptimizerConfig(name="nag", learning_rate=1e-3,
                                                      momentum=0.99),
                            loss_fn=loss_fn, num_workers=WORKERS)
    state = trainer.init_state(0, params=params0)
    shards = partition_iid(train, WORKERS, seed=0)
    for i in range(STEPS):
        x, y = batches_for_step(shards, i, BATCH // WORKERS)
        state, m = trainer.step(state, (jnp.asarray(x), jnp.asarray(y)))
    xt, yt = jnp.asarray(test.x), jnp.asarray(test.y)
    acc0 = float(simple.accuracy(simple.mlp_logits(trainer.rank0_params(state), xt), yt))
    acca = float(simple.accuracy(simple.mlp_logits(trainer.consensus_params(state), xt), yt))
    mb = float(m["comm_bytes"]) / 1e6
    label = method if codec == "none" else f"{method}+{codec}"
    print(f"{label:20s} rank0_acc={acc0:.4f} aggregate_acc={acca:.4f} "
          f"loss={float(m['loss']):.4f} comm={mb:8.2f} MB/worker")
    return acca, mb


def train_one_async(method: str, train, test, **proto_kw):
    """The same protocol on the virtual-time async engine under lognormal
    stragglers: one facade ``step`` = one event window; metrics gain
    ``virtual_time`` and the live staleness accumulators."""
    proto = ProtocolConfig(method=method, topology="uniform", **proto_kw)
    params0, _ = simple.init_mlp(jax.random.PRNGKey(0), in_dim=784, hidden=128,
                                 depth=2, num_classes=10)

    def loss_fn(params, x, y):
        return simple.xent_loss(simple.mlp_logits(params, x), y)

    trainer = GossipTrainer(
        engine="async", protocol=proto,
        hetero=HeteroConfig(time_model="lognormal", sigma=0.6),
        optimizer=OptimizerConfig(name="nag", learning_rate=1e-3, momentum=0.99),
        loss_fn=loss_fn, num_workers=WORKERS)
    state = trainer.init_state(0, params=params0)
    shards = partition_iid(train, WORKERS, seed=0)
    # one facade step = one event WINDOW (often a single worker under
    # stragglers), so budget total worker-steps, not lockstep global steps
    windows = done = 0
    while done < WORKERS * STEPS:
        x, y = batches_for_step(shards, windows, BATCH // WORKERS)
        state, m = trainer.step(state, (jnp.asarray(x), jnp.asarray(y)))
        windows += 1
        done += int(m["window_size"])
    xt, yt = jnp.asarray(test.x), jnp.asarray(test.y)
    acca = float(simple.accuracy(simple.mlp_logits(trainer.consensus_params(state), xt), yt))
    events = max(int(state.proto.stale_events), 1)
    print(f"{method+'+async':20s} aggregate_acc={acca:.4f} "
          f"virtual_time={float(m['virtual_time']):8.1f} "
          f"mean_staleness={float(state.proto.stale_time) / events:.2f}s "
          f"({int(state.proto.stale_steps) / events:.2f} steps) over {events} exchanges")
    return acca


def main():
    print("registered protocols:", ", ".join(available_protocols()))
    train, test = load_mnist(num_train=25600, num_test=4000)
    print(f"\n== {WORKERS} workers, {STEPS} steps, effective batch {BATCH} ==")
    acc_eg, mb_eg = train_one("elastic_gossip", train, test,
                              comm_probability=0.125, moving_rate=0.5)
    # same protocol with the int8 wire codec: ~4x fewer bytes again, and the
    # reported comm_bytes are the true (compressed) egress
    acc_q8, mb_q8 = train_one("elastic_gossip", train, test, codec="q8",
                              comm_probability=0.125, moving_rate=0.5)
    # heterogeneous fleet: same protocol, virtual-time async engine,
    # lognormal stragglers (repro.hetero)
    train_one_async("elastic_gossip", train, test,
                    comm_probability=0.125, moving_rate=0.5)
    acc_ar, mb_ar = train_one("allreduce", train, test)
    print(f"\nElastic Gossip reaches {acc_eg:.1%} vs All-reduce {acc_ar:.1%} "
          f"while sending {mb_eg:.1f} MB vs {mb_ar:.1f} MB per worker "
          f"(~{mb_ar / max(mb_eg, 1e-9):.0f}x less communication — paper Tables 4.1/4.3); "
          f"the q8 wire codec keeps {acc_q8:.1%} at {mb_q8:.1f} MB "
          f"(~{mb_ar / max(mb_q8, 1e-9):.0f}x total).")


if __name__ == "__main__":
    main()
