"""Serve a reduced model through the live-serving stack: publish weights onto
a SnapshotBus, prefill a prompt batch, stream tokens through a LiveServer —
and hot-swap to a newly published snapshot mid-stream (the train-while-serve
mechanic of repro.serve, here with hand-published snapshots so the example
stays standalone).

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2_9b --tokens 16
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import make_serve_program
from repro.common.config import MeshConfig
from repro.configs import ARCH_IDS, get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tr
from repro.serve import LiveServer, SnapshotBus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    mesh_cfg = MeshConfig(data=1, model=1, pods=1, workers_per_pod=1)
    mesh = make_host_mesh(1)
    prog = make_serve_program(mesh, mesh_cfg, cfg, batch=args.batch,
                              max_len=64, param_dtype=jnp.float32,
                              cache_dtype=jnp.float32, with_prefill=True)

    # the bus is the training->serving handoff; here the "trainer" is two
    # hand-published parameter versions (a GossipTrainer publishes the same
    # way through its publish_every hook — see repro.launch.serve)
    bus = SnapshotBus()
    bus.publish_params(tr.init_lm(jax.random.PRNGKey(0), cfg)[0], train_step=0)
    server = LiveServer(prog, bus)
    server.maybe_swap()

    key = jax.random.PRNGKey(1)
    if cfg.audio is not None:
        prompt = jax.random.randint(key, (args.batch, cfg.audio.num_codebooks, 8), 0, cfg.vocab_size)
        cond = jnp.zeros((args.batch, cfg.audio.num_cond_tokens, cfg.d_model))
    else:
        prompt = jax.random.randint(key, (args.batch, 8), 0, cfg.vocab_size)
        cond = (jnp.zeros((args.batch, cfg.vlm.num_image_tokens, cfg.vlm.image_embed_dim))
                if cfg.vlm is not None else None)

    logits, cache = server.prefill(prompt, cond)
    print(f"prefilled batch={args.batch} under snapshot seq={server.seq}; "
          f"decoding {args.tokens} tokens...")
    outs = []
    for t in range(args.tokens):
        if t == args.tokens // 2:
            # mid-stream: a new version lands on the bus; the server picks it
            # up BETWEEN decode batches (tokens before this boundary are
            # unaffected — the hot-swap determinism contract)
            bus.publish_params(tr.init_lm(jax.random.PRNGKey(42), cfg)[0],
                               train_step=100)
            if server.maybe_swap():
                print(f"  hot-swapped to snapshot seq={server.seq} at token {t} "
                      f"({server.swap_stats()['swap_pause_max_s'] * 1e3:.1f} ms pause)")
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = nxt[..., None] if cfg.audio is None else nxt[..., None]
        if cfg.audio is not None and tok.ndim == 2:
            tok = tok[:, :, None]
        logits, cache = server.decode(cache, tok, cond)
        outs.append(nxt)
    stream = jnp.stack(outs, axis=-1)
    print("decoded token ids (request 0):", stream.reshape(args.batch, -1)[0][:16])
    print("OK — live batched KV-cache decode (with one hot swap) ran end to end.")


if __name__ == "__main__":
    main()
