"""Serve a reduced model with batched decode requests: prefill a prompt batch,
then stream tokens with the KV-cache serve engine (greedy sampling).

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2_9b --tokens 16
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import make_serve_program
from repro.common.config import MeshConfig
from repro.configs import ARCH_IDS, get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    mesh_cfg = MeshConfig(data=1, model=1, pods=1, workers_per_pod=1)
    mesh = make_host_mesh(1)
    prog = make_serve_program(mesh, mesh_cfg, cfg, batch=args.batch,
                              max_len=64, param_dtype=jnp.float32,
                              cache_dtype=jnp.float32, with_prefill=True)
    params, _ = tr.init_lm(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    if cfg.audio is not None:
        prompt = jax.random.randint(key, (args.batch, cfg.audio.num_codebooks, 8), 0, cfg.vocab_size)
        cond = jnp.zeros((args.batch, cfg.audio.num_cond_tokens, cfg.d_model))
    else:
        prompt = jax.random.randint(key, (args.batch, 8), 0, cfg.vocab_size)
        cond = (jnp.zeros((args.batch, cfg.vlm.num_image_tokens, cfg.vlm.image_embed_dim))
                if cfg.vlm is not None else None)

    logits, cache = prog.prefill_fn(params, prompt, cond)
    print(f"prefilled batch={args.batch}; decoding {args.tokens} tokens...")
    outs = []
    for _ in range(args.tokens):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = nxt[..., None] if cfg.audio is None else nxt[..., None]
        if cfg.audio is not None and tok.ndim == 2:
            tok = tok[:, :, None]
        logits, cache = prog.decode_fn(params, cache, tok, cond)
        outs.append(nxt)
    stream = jnp.stack(outs, axis=-1)
    print("decoded token ids (request 0):", stream.reshape(args.batch, -1)[0][:16])
    print("OK — batched KV-cache decode ran end to end.")


if __name__ == "__main__":
    main()
