"""The paper's core experiment, end to end: the MNIST MLP (§4.1) trained with
Elastic Gossip vs Gossiping SGD vs All-reduce on 4 workers (exact Alg. 4/5
semantics via the simulation engine), reporting Rank-0 and Aggregate accuracy
like Table 4.1.

Everything runs through the ``repro.api.GossipTrainer`` facade over the
flat-resident ``FlatState`` (params live as flat per-dtype buffers; the
Rank-0 / Aggregate evaluations read the lazy ``state.params`` views at the
end) — see examples/quickstart.py for the surface tour.

    PYTHONPATH=src REPRO_BENCH_STEPS=400 python examples/mnist_gossip.py
"""
from benchmarks.common import CSV_HEADER, run_config


def main():
    print(CSV_HEADER)
    for label, method, p in [("AR-4", "allreduce", 0.0),
                             ("EG-4-0.125", "elastic_gossip", 0.125),
                             ("GS-4-0.125", "gossiping_pull", 0.125),
                             ("NC-4", "none", 0.0)]:
        r = run_config(method, 4, p=p, alpha=0.5, label=label, task="mnist")
        print(r.csv(), flush=True)


if __name__ == "__main__":
    main()
