"""Beyond-paper study the thesis proposes as future work (§5): gossip under
biased/skewed data partitions. Dirichlet label-skew across workers — gossip's
consensus pressure vs. heterogeneous local objectives.

    PYTHONPATH=src python examples/skewed_partitions.py
"""
from benchmarks.common import CSV_HEADER, run_config
from repro.data.partition import partition_dirichlet
from repro.data.synthetic import load_mnist


def main():
    train, test = load_mnist(num_train=12800, num_test=2000)
    print(CSV_HEADER)
    import benchmarks.common as bc
    for alpha_skew in (100.0, 0.5, 0.1):
        # monkey-patch the partitioner for this experiment
        orig = bc.partition_iid
        bc.partition_iid = lambda ds, W, seed: partition_dirichlet(ds, W, alpha_skew, seed)
        try:
            for label, method, p in [(f"EG-skew{alpha_skew}", "elastic_gossip", 0.125),
                                     (f"NC-skew{alpha_skew}", "none", 0.0)]:
                r = run_config(method, 4, p=p, alpha=0.5, label=label, task="mnist",
                               train=train, test=test, steps=200)
                print(r.csv(), flush=True)
        finally:
            bc.partition_iid = orig


if __name__ == "__main__":
    main()
